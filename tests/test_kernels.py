"""Per-kernel allclose sweeps: every Pallas kernel vs its ref.py pure-jnp
oracle across shapes and value regimes (interpret mode executes the kernel
body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GLavaSketch, SketchConfig, queries
from repro.core.hashing import make_hash_family
from repro.kernels.closure.ops import transitive_closure as closure_pallas
from repro.kernels.closure.ref import closure_step_ref
from repro.kernels.closure.kernel import closure_step_pallas
from repro.kernels.countsketch.ops import countsketch
from repro.kernels.countsketch.ref import countsketch_ref
from repro.kernels.flow.ops import flows
from repro.kernels.flow.ref import flows_ref
from repro.kernels.ingest.ops import sketch_ingest
from repro.kernels.ingest.ref import sketch_ingest_ref
from repro.kernels.ingest_fused.ops import fused_ingest
from repro.kernels.ingest_fused.ref import fused_ingest_ref
from repro.kernels.query.ops import edge_query_cells, edge_query_min
from repro.kernels.query.ref import edge_query_min_ref, edge_query_ref
from repro.core import reach as reach_mod
from repro.train.compression import CompressorConfig, init_compressor, _sketch

RNG = np.random.default_rng(7)


INGEST_SHAPES = [
    (1, 64, 64, 33),
    (2, 256, 256, 512),
    (3, 300, 200, 1000),
    (4, 512, 128, 2048),
]


@pytest.mark.parametrize("d,wr,wc,b", INGEST_SHAPES)
def test_ingest_kernel_matches_ref(d, wr, wc, b):
    # integer-valued counters/weights: the paper's counting regime, where the
    # kernel is bit-exact vs the scatter oracle (fp32 ints < 2**24)
    counters = jnp.asarray(RNG.integers(0, 1000, (d, wr, wc)), jnp.float32)
    rows = jnp.asarray(RNG.integers(0, wr, (d, b)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, wc, (d, b)), jnp.int32)
    w = jnp.asarray(RNG.integers(1, 9, b), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sketch_ingest(counters, rows, cols, w)),
        np.asarray(sketch_ingest_ref(counters, rows, cols, w)),
    )


def test_ingest_kernel_fp_weights_close():
    counters = jnp.zeros((2, 128, 128), jnp.float32)
    rows = jnp.asarray(RNG.integers(0, 128, (2, 700)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, 128, (2, 700)), jnp.int32)
    w = jnp.asarray(RNG.normal(0, 1, 700), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(sketch_ingest(counters, rows, cols, w)),
        np.asarray(sketch_ingest_ref(counters, rows, cols, w)),
        rtol=1e-6, atol=1e-5,
    )


@pytest.mark.parametrize("d,wr,wc,q", [(1, 64, 64, 17), (3, 256, 512, 300), (4, 300, 300, 1024)])
def test_query_kernel_matches_ref(d, wr, wc, q):
    counters = jnp.asarray(RNG.integers(0, 100, (d, wr, wc)), jnp.float32)
    rows = jnp.asarray(RNG.integers(0, wr, (d, q)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, wc, (d, q)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(edge_query_cells(counters, rows, cols)),
        np.asarray(edge_query_ref(counters, rows, cols)),
    )


@pytest.mark.parametrize(
    "d,wr,wc,q", [(1, 64, 64, 17), (3, 256, 512, 300), (4, 300, 300, 1024)]
)
def test_fused_multi_query_kernel_matches_ref(d, wr, wc, q):
    """The fused kernel's in-pass Γ (min over d) bit-matches the jnp oracle."""
    counters = jnp.asarray(RNG.integers(0, 100, (d, wr, wc)), jnp.float32)
    rows = jnp.asarray(RNG.integers(0, wr, (d, q)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, wc, (d, q)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(edge_query_min(counters, rows, cols)),
        np.asarray(edge_query_min_ref(counters, rows, cols)),
    )


def test_query_kernel_end_to_end_matches_core():
    cfg = SketchConfig(depth=3, width_rows=128, width_cols=128)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src = jnp.asarray(RNG.integers(0, 500, 400), jnp.uint32)
    dst = jnp.asarray(RNG.integers(0, 500, 400), jnp.uint32)
    sk = sk.update(src, dst)
    from repro.kernels.query.ops import edge_query as kernel_eq

    np.testing.assert_array_equal(
        np.asarray(kernel_eq(sk, src[:100], dst[:100])),
        np.asarray(queries.edge_query(sk, src[:100], dst[:100])),
    )


@pytest.mark.parametrize("w", [64, 256, 300])
def test_closure_step_matches_ref(w):
    a = (RNG.random((w, w)) < 0.02).astype(np.float32)
    if w % 256 == 0:
        out = np.asarray(closure_step_pallas(jnp.asarray(a)))
        np.testing.assert_array_equal(out, np.asarray(closure_step_ref(jnp.asarray(a))))
    # full closure (auto-padding path) vs jnp reference closure
    got = np.asarray(closure_pallas(jnp.asarray(a)))
    ref = np.asarray(reach_mod.transitive_closure(jnp.asarray(a)))
    np.testing.assert_array_equal(got, ref)


def test_closure_batched_over_sketches():
    a = (RNG.random((3, 64, 64)) < 0.03).astype(np.float32)
    got = np.asarray(closure_pallas(jnp.asarray(a)))
    ref = np.asarray(reach_mod.transitive_closure(jnp.asarray(a)))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("d,wr,wc", [(1, 64, 64), (3, 256, 512), (4, 300, 200)])
def test_flow_kernel_matches_ref(d, wr, wc):
    counters = jnp.asarray(RNG.integers(0, 50, (d, wr, wc)), jnp.float32)
    rs, cs = flows(counters)
    rs_ref, cs_ref = flows_ref(counters)
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(rs_ref))
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(cs_ref))


def test_flow_point_query_matches_core():
    cfg = SketchConfig(depth=3, width_rows=200, width_cols=200)
    sk = GLavaSketch.empty(cfg, jax.random.key(1))
    src = jnp.asarray(RNG.integers(0, 100, 300), jnp.uint32)
    dst = jnp.asarray(RNG.integers(0, 100, 300), jnp.uint32)
    sk = sk.update(src, dst)
    from repro.kernels.flow.ops import node_in_flow, node_out_flow

    keys = src[:20]
    np.testing.assert_array_equal(
        np.asarray(node_in_flow(sk, keys)), np.asarray(queries.node_in_flow(sk, keys))
    )
    np.testing.assert_array_equal(
        np.asarray(node_out_flow(sk, keys)), np.asarray(queries.node_out_flow(sk, keys))
    )


@pytest.mark.parametrize("n,w,d", [(100, 64, 3), (5000, 256, 5), (3000, 300, 4)])
def test_countsketch_kernel_matches_ref(n, w, d):
    fam = make_hash_family(jax.random.key(2), d, w)
    vec = jnp.asarray(RNG.normal(0, 1, n), jnp.float32)
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = fam(idx).astype(jnp.int32)
    s = fam.signs(idx)
    got = np.asarray(countsketch(vec, fam))
    ref = np.asarray(countsketch_ref(vec, h, s, w))
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-4)


def test_countsketch_kernel_matches_compression_module():
    ccfg = CompressorConfig(depth=4, width=256)
    st = init_compressor(ccfg, 1000, jax.random.key(3))
    vec = jnp.asarray(RNG.normal(0, 1, 1000), jnp.float32)
    got = np.asarray(countsketch(vec, st.hash))
    ref = np.asarray(_sketch(st, vec))
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-4)


FUSED_SHAPES = [
    (1, 64, 64, 33),
    (2, 256, 128, 512),
    (3, 300, 200, 1000),
]


@pytest.mark.parametrize("d,wr,wc,b", FUSED_SHAPES)
def test_fused_ingest_kernel_matches_ref(d, wr, wc, b):
    """One-pass fused kernel (interpret mode) vs the three-pass jnp twin:
    counters, row_flows, col_flows bit-equal, touched bitmap identical —
    including -1 sentinel rows (padding slots must be inert everywhere)."""
    counters = jnp.asarray(RNG.integers(0, 1000, (d, wr, wc)), jnp.float32)
    rf = jnp.asarray(RNG.integers(0, 1000, (d, wr)), jnp.float32)
    cf = jnp.asarray(RNG.integers(0, 1000, (d, wc)), jnp.float32)
    rows = jnp.asarray(RNG.integers(0, wr, (d, b)), jnp.int32)
    # sprinkle padding sentinels into every depth
    sentinel = RNG.random((d, b)) < 0.1
    rows = jnp.where(jnp.asarray(sentinel), -1, rows)
    cols = jnp.asarray(RNG.integers(0, wc, (d, b)), jnp.int32)
    w = jnp.asarray(RNG.integers(1, 9, b), jnp.float32)
    got = fused_ingest(counters, rf, cf, rows, cols, w, interpret=True)
    ref = fused_ingest_ref(counters, rf, cf, rows, cols, w)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_fused_ingest_fp_weights_close():
    counters = jnp.zeros((2, 128, 128), jnp.float32)
    rf = jnp.zeros((2, 128), jnp.float32)
    cf = jnp.zeros((2, 128), jnp.float32)
    rows = jnp.asarray(RNG.integers(0, 128, (2, 700)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, 128, (2, 700)), jnp.int32)
    w = jnp.asarray(RNG.normal(0, 1, 700), jnp.float32)
    got = fused_ingest(counters, rf, cf, rows, cols, w, interpret=True)
    ref = fused_ingest_ref(counters, rf, cf, rows, cols, w)
    for g, r in zip(got[:3], ref[:3]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=1e-6, atol=1e-5
        )
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(ref[3]))


def test_fused_ingest_sentinel_rows_are_inert():
    """An all-sentinel batch changes nothing: not counters, not either
    register plane, and the touched bitmap stays empty."""
    counters = jnp.asarray(RNG.integers(0, 50, (2, 64, 64)), jnp.float32)
    rf = jnp.asarray(RNG.integers(0, 50, (2, 64)), jnp.float32)
    cf = jnp.asarray(RNG.integers(0, 50, (2, 64)), jnp.float32)
    rows = jnp.full((2, 40), -1, jnp.int32)
    cols = jnp.asarray(RNG.integers(0, 64, (2, 40)), jnp.int32)
    w = jnp.ones(40, jnp.float32)
    got = fused_ingest(counters, rf, cf, rows, cols, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(counters))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(rf))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(cf))
    assert not bool(np.asarray(got[3]).any())


def test_sketch_update_fused_matches_scatter_composition():
    """GLavaSketch.update_fused == update(backend='scatter') bit-exactly,
    and its touched bitmap marks exactly the hashed rows of the batch."""
    cfg = SketchConfig(depth=3, width_rows=128, width_cols=128)
    sk = GLavaSketch.empty(cfg, jax.random.key(5))
    src = jnp.asarray(RNG.integers(0, 900, 600), jnp.uint32)
    dst = jnp.asarray(RNG.integers(0, 900, 600), jnp.uint32)
    fused, touched = sk.update_fused(src, dst)
    oracle = sk.update(src, dst, backend="scatter", preagg="off")
    np.testing.assert_array_equal(
        np.asarray(fused.counters), np.asarray(oracle.counters)
    )
    np.testing.assert_array_equal(
        np.asarray(fused.row_flows), np.asarray(oracle.row_flows)
    )
    np.testing.assert_array_equal(
        np.asarray(fused.col_flows), np.asarray(oracle.col_flows)
    )
    rows = np.asarray(sk.row_hash(src))  # (d, B)
    want = np.zeros((3, 128), bool)
    for di in range(3):
        want[di, np.unique(rows[di])] = True
    np.testing.assert_array_equal(np.asarray(touched), want)


def test_sketch_pallas_backend_via_core_api():
    """GLavaSketch.update(backend='pallas') equals the scatter semantics."""
    cfg = SketchConfig(depth=2, width_rows=256, width_cols=256)
    sk = GLavaSketch.empty(cfg, jax.random.key(4))
    src = jnp.asarray(RNG.integers(0, 900, 600), jnp.uint32)
    dst = jnp.asarray(RNG.integers(0, 900, 600), jnp.uint32)
    a = sk.update(src, dst, backend="scatter")
    b = sk.update(src, dst, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
