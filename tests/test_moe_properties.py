"""Property tests for the MoE dispatch invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import MoEArgs, moe_block, moe_capacity


def _weights(key, e, d, f):
    ks = jax.random.split(key, 4)
    return (
        jax.random.normal(ks[0], (d, e)) / np.sqrt(d),
        jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d),
        jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d),
        jax.random.normal(ks[3], (e, f, d)) / np.sqrt(f),
    )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 1000),
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    t=st.integers(8, 64),
)
def test_moe_capacity_ample_means_no_drops(seed, e, k, t):
    """With a large capacity factor, every token's output must be a convex
    (renormalized top-k) combination — i.e. nonzero whenever its expert
    outputs are nonzero, and permutation of tokens commutes with dispatch."""
    d, f = 16, 32
    key = jax.random.key(seed)
    router, wg, wu, wd = _weights(key, e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 9), (t, d))
    args = MoEArgs(n_experts=e, top_k=k, capacity_factor=float(e))
    y, aux = moe_block(x, router, wg, wu, wd, args)
    assert bool(jnp.all(jnp.isfinite(y)))
    # permutation equivariance: shuffle tokens, outputs shuffle identically
    perm = np.random.default_rng(seed).permutation(t)
    y_p, _ = moe_block(x[perm], router, wg, wu, wd, args)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y)[perm], atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_moe_dropped_tokens_bounded_by_capacity(seed):
    """With capacity factor 1.0 the number of NONZERO outputs is at least
    t - sum of overflow (no spurious zeroing), and aux loss is >= 1 (its
    minimum at perfect balance)."""
    e, k, t, d, f = 4, 1, 64, 8, 16
    key = jax.random.key(seed)
    router, wg, wu, wd = _weights(key, e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 3), (t, d))
    args = MoEArgs(n_experts=e, top_k=k, capacity_factor=1.0, aux_loss_coef=1.0)
    y, aux = moe_block(x, router, wg, wu, wd, args)
    c = moe_capacity(t, args)
    nonzero = int(jnp.sum(jnp.any(y != 0, axis=-1)))
    assert nonzero <= min(t, e * c)
    # aux = E·Σ m_e c_e is positive and finite; its EXPECTED minimum is 1 at
    # balance but finite-sample anti-correlation of m and c can dip below —
    # only positivity is a true invariant (found by hypothesis).
    assert 0.0 < float(aux) < 10.0


def test_moe_grads_flow_through_dispatch():
    e, k, t, d, f = 4, 2, 32, 8, 16
    key = jax.random.key(0)
    router, wg, wu, wd = _weights(key, e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 1), (t, d))
    args = MoEArgs(n_experts=e, top_k=k, capacity_factor=4.0)

    def loss(params):
        router, wg, wu, wd = params
        y, aux = moe_block(x, router, wg, wu, wd, args)
        return jnp.sum(y * y) + aux

    grads = jax.grad(loss)((router, wg, wu, wd))
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.abs(g).sum()) > 0  # every tensor gets gradient
