"""Numerical equivalence of the shard_map EP dispatch vs the GSPMD gather
dispatch (the §Perf optimization must not change the math).  Runs on an
8-device subprocess mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses as dc
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.layers import MoEArgs, moe_block, moe_ffn_sharded

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, S, D, F = 4, 16, 32, 64

    for partition, E, K in (("expert", 8, 2), ("ffn", 4, 2)):
        key = jax.random.key(0)
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
        router = jax.random.normal(ks[1], (D, E)) / np.sqrt(D)
        wg = jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)
        wu = jax.random.normal(ks[3], (E, D, F)) / np.sqrt(D)
        wd = jax.random.normal(ks[4], (E, F, D)) / np.sqrt(F)

        # reference: unsharded dense-capacity moe_block with GLOBAL capacity.
        # The sharded version routes per-device (T/8 tokens, capacity/8), so
        # to compare exactly we give both FULL capacity (factor high enough
        # that nothing is dropped).
        args_ref = MoEArgs(n_experts=E, top_k=K, capacity_factor=8.0,
                           partition=partition)
        y_ref, aux_ref = moe_block(
            x.reshape(-1, D), router, wg, wu, wd, args_ref
        )
        y_ref = y_ref.reshape(B, S, D)

        args_sh = dc.replace(args_ref, shard_dispatch=True, mesh=mesh)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
        y_sh, aux_sh = jax.jit(
            lambda *a: moe_ffn_sharded(*a, args_sh)
        )(xs, router, wg, wu, wd)
        err = float(jnp.max(jnp.abs(y_sh - y_ref)))
        print(partition, "max_err", err, "aux_ref", float(aux_ref), "aux_sh", float(aux_sh))
        assert err < 2e-5, (partition, err)
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_sharded_moe_matches_dense_dispatch():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_OK" in proc.stdout, proc.stdout
