"""Optimizer unit tests: schedule shape, clipping, dtype knobs, decay."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt


def test_lr_schedule_warmup_and_cosine():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 115, 1)]
    assert lrs[0] == 0.0
    assert lrs[5] == pytest.approx(0.5, abs=1e-6)       # linear warmup
    assert lrs[10] == pytest.approx(1.0, abs=1e-6)      # peak
    assert lrs[110] == pytest.approx(0.1, abs=1e-3)     # min_lr_frac floor
    assert all(lrs[i] >= lrs[i + 1] - 1e-9 for i in range(10, 110))  # monotone decay


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    cn = opt.global_norm(clipped)
    assert float(cn) == pytest.approx(1.0, rel=1e-5)
    # under the limit: untouched
    same, _ = opt.clip_by_global_norm(g, 100.0)
    np.testing.assert_array_equal(np.asarray(same["a"]), np.asarray(g["a"]))


def test_adamw_weight_decay_pulls_to_zero():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0, warmup_steps=0,
                          total_steps=1000, min_lr_frac=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init_adamw(cfg, params)
    zeros = {"w": jnp.zeros((4,))}
    for _ in range(50):
        params, state, _ = opt.apply_adamw(cfg, state, params, zeros)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_bf16_moments_close_to_fp32():
    rng = np.random.default_rng(0)
    grads_seq = [
        {"w": jnp.asarray(rng.normal(0, 1, (8, 8)), jnp.float32)} for _ in range(20)
    ]
    outs = {}
    for tag, (md, vd) in {
        "fp32": (jnp.float32, jnp.float32),
        "bf16": (jnp.bfloat16, jnp.bfloat16),
    }.items():
        cfg = opt.AdamWConfig(lr=1e-2, m_dtype=md, v_dtype=vd, weight_decay=0.0,
                              warmup_steps=0, total_steps=100, min_lr_frac=1.0)
        params = {"w": jnp.zeros((8, 8))}
        state = opt.init_adamw(cfg, params)
        for g in grads_seq:
            params, state, _ = opt.apply_adamw(cfg, state, params, g)
        outs[tag] = np.asarray(params["w"])
    rel = np.abs(outs["bf16"] - outs["fp32"]).max() / np.abs(outs["fp32"]).max()
    assert rel < 0.05, rel  # arctic's memory-fit knob costs <5% drift here


def test_step_counter_and_bias_correction():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=10, min_lr_frac=1.0)
    params = {"w": jnp.zeros(())}
    state = opt.init_adamw(cfg, params)
    g = {"w": jnp.asarray(1.0)}
    params, state, m = opt.apply_adamw(cfg, state, params, g)
    assert int(state.step) == 1
    # first Adam step with bias correction moves by ~lr
    assert float(params["w"]) == pytest.approx(-0.1, rel=1e-3)
