"""Pipeline-parallel schedule correctness (4-device subprocess): GPipe
pipeline output == sequential stage composition, and the bubble math."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import pipeline_bubble_fraction

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.pipeline import microbatch, pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    S, M, MB, D = 4, 8, 4, 16
    key = jax.random.key(0)
    Ws = jax.random.normal(key, (S, D, D)) / np.sqrt(D)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (S, D)) * 0.1

    def stage_fn(params, x):
        w, b = params
        return jnp.tanh(x @ w + b)

    x = jax.random.normal(jax.random.fold_in(key, 2), (M * MB, D))
    xm = microbatch(x, M)

    out = pipeline_apply(stage_fn, (Ws, bs), xm, mesh)

    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s] + bs[s])
    ref = ref.reshape(M, MB, D)

    err = float(jnp.max(jnp.abs(out - ref)))
    print("pipeline max err:", err)
    assert err < 1e-5
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_OK" in proc.stdout, proc.stdout


def test_bubble_fraction():
    assert pipeline_bubble_fraction(4, 8) == pytest.approx(3 / 11)
    assert pipeline_bubble_fraction(1, 8) == 0.0
    # the DESIGN.md claim: at assigned depths with few microbatches the
    # bubble is material; EP+FSDP avoids it
    assert pipeline_bubble_fraction(8, 16) > 0.3
