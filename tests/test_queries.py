"""Query-estimator tests against exact answers on the paper's Fig. 1 stream
and randomized streams."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GLavaSketch, SketchConfig, queries, reach, fnv1a_label

# The paper's Fig. 1 stream: (a,b) (a,c) (b,c)... with the aggregate weights
# implied by Figs. 2/5: ab:5? We use the edge list readable from Fig. 1:
# a->b (weight 5 shown in Fig 2 bucket), but for exactness we build a small
# concrete multigraph of our own with known counts.
LABELS = list("abcdefg")
KEY = {l: fnv1a_label(l) for l in LABELS}
EDGES = [
    ("a", "b"), ("a", "b"), ("a", "c"), ("b", "c"), ("b", "a"),
    ("c", "e"), ("c", "e"), ("c", "e"), ("d", "g"), ("g", "b"),
    ("e", "d"), ("f", "a"), ("b", "f"), ("b", "a"),
]


def _fig1_sketch(cfg=None, key=0):
    cfg = cfg or SketchConfig(depth=4, width_rows=256, width_cols=256)
    sk = GLavaSketch.empty(cfg, jax.random.key(key))
    src = jnp.asarray([KEY[s] for s, _ in EDGES], jnp.uint32)
    dst = jnp.asarray([KEY[d] for _, d in EDGES], jnp.uint32)
    return sk.update(src, dst)


def _k(*labels):
    return jnp.asarray([KEY[l] for l in labels], jnp.uint32)


def test_edge_query_exact_and_overestimate():
    sk = _fig1_sketch()
    cnt = collections.Counter(EDGES)
    est = np.asarray(queries.edge_query(sk, _k("a", "c", "g"), _k("b", "e", "b")))
    ex = np.array([cnt[("a", "b")], cnt[("c", "e")], cnt[("g", "b")]], float)
    assert np.all(est >= ex)
    # With w=256 >> 7 nodes, collisions are overwhelmingly unlikely.
    np.testing.assert_array_equal(est, ex)


def test_edge_query_dtype_stability():
    """The undirected self-loop correction must not promote integer counters
    to float (est / 2.0 used to)."""
    import dataclasses

    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64, directed=False)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src = jnp.asarray([5, 5, 9], jnp.uint32)
    dst = jnp.asarray([5, 7, 9], jnp.uint32)  # two self-loops + one edge
    sk = sk.update(src, dst, jnp.asarray([3, 2, 1], jnp.float32))
    for dtype in (jnp.float32, jnp.int32):
        cast = dataclasses.replace(
            sk,
            counters=sk.counters.astype(dtype),
            row_flows=sk.row_flows.astype(dtype),
            col_flows=sk.col_flows.astype(dtype),
        )
        est = queries.edge_query(cast, src, dst)
        assert est.dtype == dtype, f"promoted to {est.dtype}"
        # self-loop halving stays exact (loop mass is always even)
        np.testing.assert_array_equal(np.asarray(est), [3, 2, 1])


def test_point_queries_match_exact():
    sk = _fig1_sketch()
    in_b = sum(1 for _, d in EDGES if d == "b")
    out_b = sum(1 for s, _ in EDGES if s == "b")
    est_in = float(queries.node_in_flow(sk, _k("b"))[0])
    est_out = float(queries.node_out_flow(sk, _k("b"))[0])
    assert est_in >= in_b and est_out >= out_b
    assert est_in == in_b and est_out == out_b  # w >> n


def test_monitor_step_alarm():
    sk = _fig1_sketch()
    in_b = sum(1 for _, d in EDGES if d == "b")
    alarm, sk2 = queries.monitor_step(
        sk, _k("g"), _k("b"), jnp.ones(1), _k("b")[0], theta=in_b + 0.5
    )
    assert bool(alarm)  # new edge pushes over θ
    alarm2, _ = queries.monitor_step(
        sk, _k("g"), _k("b"), jnp.ones(1), _k("b")[0], theta=in_b + 10
    )
    assert not bool(alarm2)
    # step 3 updated all d sketches (each gains the edge weight)
    assert float(sk2.counters.sum()) == float(sk.counters.sum()) + sk.depth


def test_reachability_no_false_negatives():
    """Hashing maps a real path to a path in the sketch — r(a,b) true implies
    r̃(a,b) true, for ANY hash draw (paper Section 4.3 one-sided error)."""
    for seed in range(5):
        cfg = SketchConfig(depth=3, width_rows=8, width_cols=8)  # tiny, collision-heavy
        sk = GLavaSketch.empty(cfg, jax.random.key(seed))
        src = jnp.asarray([1, 2, 3, 10], jnp.uint32)
        dst = jnp.asarray([2, 3, 4, 11], jnp.uint32)
        sk = sk.update(src, dst)
        r = queries.reach_query(
            sk,
            jnp.asarray([1, 1, 2], jnp.uint32),
            jnp.asarray([4, 3, 4], jnp.uint32),
        )
        assert bool(jnp.all(r)), f"false negative at seed {seed}"


def test_reachability_precision_with_width():
    """False-positive rate must drop as w grows (collision argument)."""
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, 50, 60), jnp.uint32)
    dst = jnp.asarray(rng.integers(50, 100, 60), jnp.uint32)  # bipartite: no 2-hop back-paths
    fp = {}
    for w in (8, 128):
        cfg = SketchConfig(depth=4, width_rows=w, width_cols=w)
        sk = GLavaSketch.empty(cfg, jax.random.key(1)).update(src, dst)
        # dst-side nodes cannot reach src-side nodes in the true graph
        q_from = jnp.asarray(rng.integers(50, 100, 100), jnp.uint32)
        q_to = jnp.asarray(rng.integers(0, 50, 100), jnp.uint32)
        r = np.asarray(queries.reach_query(sk, q_from, q_to))
        fp[w] = r.mean()
    assert fp[128] <= fp[8]
    assert fp[128] < 0.2


def test_subgraph_semantics_zero_propagation():
    sk = _fig1_sketch()
    # {(a,b),(a,c)} exists: estimate >= 3 (2+1)
    est = float(queries.subgraph_query(sk, _k("a", "a"), _k("b", "c")))
    assert est >= 3
    # a subgraph with a non-existent edge must estimate 0 (revised semantics)
    est0 = float(queries.subgraph_query(sk, _k("a", "g"), _k("b", "a")))
    assert est0 == 0.0
    est0o = float(queries.subgraph_query_opt(sk, _k("a", "g"), _k("b", "a")))
    assert est0o == 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_fopt_leq_f(seed):
    """Paper Section 4.4: f̃'(Q) <= f̃(Q)."""
    rng = np.random.default_rng(seed)
    cfg = SketchConfig(depth=3, width_rows=16, width_cols=16)
    sk = GLavaSketch.empty(cfg, jax.random.key(seed))
    src = jnp.asarray(rng.integers(0, 30, 50), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 30, 50), jnp.uint32)
    sk = sk.update(src, dst)
    qs, qd = src[:4], dst[:4]
    f = float(queries.subgraph_query(sk, qs, qd))
    fo = float(queries.subgraph_query_opt(sk, qs, qd))
    assert fo <= f + 1e-5


def test_wildcard_queries():
    sk = _fig1_sketch()
    out_a = sum(1 for s, _ in EDGES if s == "a")
    est = float(queries.wildcard_edge_query(sk, _k("a"), None)[0])
    assert est == out_a
    in_c = sum(1 for _, d in EDGES if d == "c")
    est2 = float(queries.wildcard_edge_query(sk, None, _k("c"))[0])
    assert est2 == in_c
    total = float(queries.wildcard_edge_query(sk, None, None)[0])
    assert total == len(EDGES)


def test_bound_wildcard_common_neighbors():
    sk = _fig1_sketch()
    # Q6: {(*1, b), (b? no — (c, *1)}: pairs (u->b, c->u). True pairs:
    # u->b from {a(x2... a->b twice), g->b}; c->u edges: c->e x3.
    # Overlap u in {e}: u=e needs e->b (absent). So count = 0.
    est = float(queries.bound_wildcard_path2(sk, _k("b"), _k("c"))[0])
    assert est >= 0
    # Construct a positive case: pairs (u->a, b->u): u=f: f->a yes, b->f yes -> 1*1
    est2 = float(queries.bound_wildcard_path2(sk, _k("a"), _k("b"))[0])
    true2 = 2 * 1  # u=a? a->a no. u=f: f->a(1) and b->f(1) ->1; u=a no; also b->a(x2) & ... u must satisfy u->a and b->u: u=f only -> 1. Plus u=b? b->a yes (2), b->b no.
    assert est2 >= 1


def test_triangle_query():
    cfg = SketchConfig(depth=4, width_rows=128, width_cols=128)
    sk = GLavaSketch.empty(cfg, jax.random.key(3))
    src = jnp.asarray([1, 2, 3], jnp.uint32)
    dst = jnp.asarray([2, 3, 1], jnp.uint32)
    sk = sk.update(src, dst)
    t = float(
        queries.triangle_query(
            sk,
            jnp.asarray(1, jnp.uint32),
            jnp.asarray(2, jnp.uint32),
            jnp.asarray(3, jnp.uint32),
        )
    )
    assert t == 3.0  # sum of the three unit edges
    t0 = float(
        queries.triangle_query(
            sk,
            jnp.asarray(1, jnp.uint32),
            jnp.asarray(3, jnp.uint32),
            jnp.asarray(2, jnp.uint32),
        )
    )
    assert t0 == 0.0  # reversed triangle absent


def test_sketch_pagerank_is_distribution():
    sk = _fig1_sketch()
    pr = np.asarray(queries.sketch_pagerank(sk, iters=16))
    np.testing.assert_allclose(pr.sum(axis=1), 1.0, atol=1e-3)
    assert np.all(pr >= 0)


def test_transitive_closure_matches_bfs():
    rng = np.random.default_rng(4)
    n = 32
    adj = (rng.random((n, n)) < 0.06).astype(np.float32)
    closure = np.asarray(reach.transitive_closure(jnp.asarray(adj)))
    # Floyd-Warshall reference
    ref = adj > 0
    ref = ref | np.eye(n, dtype=bool)
    for k in range(n):
        ref = ref | (ref[:, k : k + 1] & ref[k : k + 1, :])
    np.testing.assert_array_equal(closure, ref)


def test_khop_reach():
    adj = jnp.asarray(
        np.array([[0, 1, 0], [0, 0, 1], [0, 0, 0]], np.float32)
    )
    r1 = np.asarray(reach.k_hop_reach(adj, 1))
    assert r1[0, 1] and not r1[0, 2]
    r2 = np.asarray(reach.k_hop_reach(adj, 2))
    assert r2[0, 2]
