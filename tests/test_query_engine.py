"""Query-plane tests: maintained flow registers (bit-match recomputed sums
under arbitrary update/merge/window/scale sequences), register-served point
queries (no full-counter reduction in the jaxpr), the monitor oracle,
heavy-hitter one-sidedness, the QueryEngine dispatch (padding/chunking,
backend equality, epoch-tagged closure cache), and checkpoint schema
evolution for register-less sketches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    GLavaSketch,
    QueryEngine,
    SketchConfig,
    SlidingWindowSketch,
    queries,
)


def _stream(rng, n, n_nodes=200):
    return (
        jnp.asarray(rng.integers(0, n_nodes, n), jnp.uint32),
        jnp.asarray(rng.integers(0, n_nodes, n), jnp.uint32),
        jnp.asarray(rng.integers(1, 6, n), jnp.float32),
    )


def _assert_registers_fresh(sk, err=""):
    """Maintained registers must BIT-match freshly recomputed marginals."""
    np.testing.assert_array_equal(
        np.asarray(sk.row_flows), np.asarray(jnp.sum(sk.counters, axis=2)),
        err_msg=f"row register drift {err}",
    )
    np.testing.assert_array_equal(
        np.asarray(sk.col_flows), np.asarray(jnp.sum(sk.counters, axis=1)),
        err_msg=f"col register drift {err}",
    )


# ---------------------------------------------------------------------------
# register maintenance
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    ops=st.lists(
        st.sampled_from(["update", "merge", "scale", "delete", "sequential"]),
        min_size=1,
        max_size=6,
    ),
)
def test_registers_bitmatch_recomputed_sums(seed, ops):
    rng = np.random.default_rng(seed)
    cfg = SketchConfig(depth=3, width_rows=32, width_cols=32)
    sk = GLavaSketch.empty(cfg, jax.random.key(seed % 7))
    for op in ops:
        src, dst, w = _stream(rng, int(rng.integers(1, 80)))
        if op == "update":
            sk = sk.update(src, dst, w, backend=str(rng.choice(["scatter", "onehot"])))
        elif op == "sequential":
            sk = sk.update_sequential(src, dst, w)
        elif op == "delete":
            sk = sk.delete(src, dst, w)
        elif op == "merge":
            other = GLavaSketch.empty(cfg, jax.random.key(seed % 7))
            sk = sk.merge(other.update(src, dst, w))
        elif op == "scale":
            sk = sk.scale(0.5)
        _assert_registers_fresh(sk, err=f"after {op}")


def test_registers_nonsquare_and_undirected():
    rng = np.random.default_rng(3)
    for cfg in (
        SketchConfig(depth=2, width_rows=96, width_cols=40),
        SketchConfig(depth=3, width_rows=64, width_cols=64, directed=False),
    ):
        sk = GLavaSketch.empty(cfg, jax.random.key(1))
        src, dst, w = _stream(rng, 150)
        sk = sk.update(src, dst, w)
        _assert_registers_fresh(sk, err=str(cfg))


def test_registers_conservative_update():
    rng = np.random.default_rng(4)
    cfg = SketchConfig(depth=3, width_rows=32, width_cols=32)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src, dst, w = _stream(rng, 200, n_nodes=60)
    sk = sk.update_conservative(src, dst, w)
    _assert_registers_fresh(sk, err="after conservative update")


def test_positional_construction_backfills_registers():
    """Old call sites construct GLavaSketch without registers — __post_init__
    derives them from the counters."""
    cfg = SketchConfig(depth=2, width_rows=16, width_cols=16)
    tmpl = GLavaSketch.empty(cfg, jax.random.key(0))
    counters = jnp.asarray(
        np.random.default_rng(0).integers(0, 9, (2, 16, 16)), jnp.float32
    )
    sk = GLavaSketch(counters, tmpl.row_hash, tmpl.col_hash, cfg)
    _assert_registers_fresh(sk)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    ops=st.lists(
        st.sampled_from(["update", "advance"]), min_size=1, max_size=8
    ),
)
def test_window_registers_bitmatch(seed, ops):
    rng = np.random.default_rng(seed)
    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    win = SlidingWindowSketch.empty(cfg, n_slices=3, key=jax.random.key(0))
    for op in ops:
        if op == "update":
            src, dst, w = _stream(rng, int(rng.integers(1, 40)))
            win = win.update(src, dst, w)
        else:
            win = win.advance()
    # per-slice registers match per-slice counter marginals...
    np.testing.assert_array_equal(
        np.asarray(win.row_flows), np.asarray(jnp.sum(win.slices, axis=3))
    )
    np.testing.assert_array_equal(
        np.asarray(win.col_flows), np.asarray(jnp.sum(win.slices, axis=2))
    )
    # ...and the materialized window sketch inherits exact registers.
    _assert_registers_fresh(win.window_sketch(), err="window_sketch")


# ---------------------------------------------------------------------------
# register-served queries: no full-counter reduction in the jaxpr
# ---------------------------------------------------------------------------


# The jaxpr walking + reduction detection lives in the shared analysis
# plane now (repro.analysis.jaxpr_lint drives it over the whole entry-point
# registry); this test keeps the focused per-family assertions.
from repro.analysis import reduces_full_counters as _reduces_full_counters


def test_point_queries_have_no_counter_reduction():
    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    keys = jnp.zeros(8, jnp.uint32)
    shape = tuple(sk.counters.shape)
    assert not _reduces_full_counters(queries.node_in_flow, shape, sk, keys)
    assert not _reduces_full_counters(queries.node_out_flow, shape, sk, keys)
    assert not _reduces_full_counters(
        lambda s, k: queries.check_heavy_keys(s, k, 10.0), shape, sk, keys
    )

    def monitor(s, src, dst, w, watch):
        return queries.monitor_step(s, src, dst, w, watch, theta=100.0)

    src = jnp.zeros(16, jnp.uint32)
    w = jnp.ones(16, jnp.float32)
    assert not _reduces_full_counters(
        monitor, shape, sk, src, src, w, keys[0]
    )
    # sanity: the recompute path DOES reduce the counters (the checker works)
    assert _reduces_full_counters(
        lambda s, k: jnp.min(
            jnp.take_along_axis(jnp.sum(s.counters, axis=1), s.col_hash(k), axis=1),
            axis=0,
        ),
        shape,
        sk,
        keys,
    )


# ---------------------------------------------------------------------------
# monitor oracle + heavy hitters
# ---------------------------------------------------------------------------


def test_monitor_step_matches_recompute_oracle():
    rng = np.random.default_rng(5)
    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    sk = GLavaSketch.empty(cfg, jax.random.key(2))
    watch = jnp.asarray(7, jnp.uint32)
    for step in range(6):
        src, dst, w = _stream(rng, 50, n_nodes=30)
        # Oracle: in-flow from freshly recomputed column sums (the pre-PR
        # semantics), alarm decision recomputed by hand.
        oracle_sk = sk.with_counters(sk.counters)
        col_sums = jnp.sum(oracle_sk.counters, axis=1)
        h = oracle_sk.col_hash(watch[None])
        inflow = jnp.min(jnp.take_along_axis(col_sums, h, axis=1), axis=0)[0]
        hits = jnp.sum((dst == watch) * w)
        for theta in (float(inflow + hits) - 0.5, float(inflow + hits) + 10.0):
            want = bool(inflow + hits > theta)
            alarm, _ = queries.monitor_step(sk, src, dst, w, watch, theta)
            assert bool(alarm) == want, f"step {step} theta {theta}"
        _, sk = queries.monitor_step(sk, src, dst, w, watch, 1e9)
        _assert_registers_fresh(sk, err=f"after monitor step {step}")


def test_heavy_hitters_no_false_negatives():
    rng = np.random.default_rng(6)
    cfg = SketchConfig(depth=3, width_rows=16, width_cols=16)  # collision-heavy
    sk = GLavaSketch.empty(cfg, jax.random.key(3))
    n_nodes = 50
    src, dst, w = _stream(rng, 1000, n_nodes=n_nodes)
    sk = sk.update(src, dst, w)
    exact_in = np.zeros(n_nodes)
    exact_out = np.zeros(n_nodes)
    for s, d, wt in zip(np.asarray(src), np.asarray(dst), np.asarray(w)):
        exact_out[int(s)] += float(wt)
        exact_in[int(d)] += float(wt)
    keys = jnp.arange(n_nodes, dtype=jnp.uint32)
    for theta in (np.percentile(exact_in, 50), np.percentile(exact_in, 90)):
        in_flag, out_flag = queries.check_heavy_keys(sk, keys, float(theta))
        # CountMin over-estimates: every true heavy hitter MUST be flagged.
        assert np.all(np.asarray(in_flag)[exact_in > theta])
        assert np.all(np.asarray(out_flag)[exact_out > theta])


# ---------------------------------------------------------------------------
# QueryEngine dispatch
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def loaded_sketch():
    rng = np.random.default_rng(1)
    cfg = SketchConfig(depth=3, width_rows=128, width_cols=128)
    sk = GLavaSketch.empty(cfg, jax.random.key(1))
    src, dst, w = _stream(rng, 2000, n_nodes=500)
    return sk.update(src, dst, w), src, dst


@pytest.mark.parametrize("q", [1, 17, 256, 300])
def test_engine_matches_direct_queries_ragged_batches(loaded_sketch, q):
    sk, src, dst = loaded_sketch
    eng = QueryEngine("jnp")
    qs, qd = src[:q], dst[:q]
    np.testing.assert_array_equal(
        np.asarray(eng.edge(sk, qs, qd)),
        np.asarray(queries.edge_query(sk, qs, qd)),
    )
    np.testing.assert_array_equal(
        np.asarray(eng.in_flow(sk, qs)), np.asarray(queries.node_in_flow(sk, qs))
    )
    np.testing.assert_array_equal(
        np.asarray(eng.out_flow(sk, qs)),
        np.asarray(queries.node_out_flow(sk, qs)),
    )


def test_engine_chunking_matches_direct(loaded_sketch):
    sk, src, dst = loaded_sketch
    eng = QueryEngine("jnp", pad_q=8, chunk_q=16)
    q = 37  # 2 full chunks + ragged tail, tail padded 5->8
    np.testing.assert_array_equal(
        np.asarray(eng.edge(sk, src[:q], dst[:q])),
        np.asarray(queries.edge_query(sk, src[:q], dst[:q])),
    )


def test_engine_pallas_backend_matches_jnp(loaded_sketch):
    sk, src, dst = loaded_sketch
    a = QueryEngine("jnp")
    b = QueryEngine("pallas")
    qs, qd = src[:100], dst[:100]
    np.testing.assert_array_equal(
        np.asarray(a.edge(sk, qs, qd)), np.asarray(b.edge(sk, qs, qd))
    )
    rq = jnp.asarray([1, 5, 9], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(a.reach(sk, rq, rq, epoch=0)),
        np.asarray(b.reach(sk, rq, rq, epoch=0)),
    )


def test_engine_backends_dtype_agree_int_undirected():
    """Both backends must return the COUNTER dtype, including through the
    undirected self-loop correction (int stays int)."""
    import dataclasses

    cfg = SketchConfig(depth=2, width_rows=64, width_cols=64, directed=False)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src = jnp.asarray([5, 5, 9], jnp.uint32)
    dst = jnp.asarray([5, 7, 9], jnp.uint32)
    sk = sk.update(src, dst, jnp.asarray([3, 2, 1], jnp.float32))
    cast = dataclasses.replace(
        sk,
        counters=sk.counters.astype(jnp.int32),
        row_flows=sk.row_flows.astype(jnp.int32),
        col_flows=sk.col_flows.astype(jnp.int32),
    )
    got_j = QueryEngine("jnp").edge(cast, src, dst)
    got_p = QueryEngine("pallas").edge(cast, src, dst)
    assert got_j.dtype == jnp.int32
    assert got_p.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got_j), np.asarray(got_p))
    np.testing.assert_array_equal(np.asarray(got_j), [3, 2, 1])


def test_engine_heavy_and_subgraph(loaded_sketch):
    sk, src, dst = loaded_sketch
    eng = QueryEngine("jnp")
    keys = src[:33]
    in_h, out_h = eng.heavy(sk, keys, 10.0)
    ref_in, ref_out = queries.check_heavy_keys(sk, keys, 10.0)
    np.testing.assert_array_equal(np.asarray(in_h), np.asarray(ref_in))
    np.testing.assert_array_equal(np.asarray(out_h), np.asarray(ref_out))
    assert in_h.shape == keys.shape
    np.testing.assert_array_equal(
        np.asarray(eng.subgraph(sk, src[:3], dst[:3])),
        np.asarray(queries.subgraph_query(sk, src[:3], dst[:3])),
    )


def test_engine_closure_epoch_cache(loaded_sketch):
    sk, src, dst = loaded_sketch
    eng = QueryEngine("jnp")
    qs = jnp.asarray([1, 2], jnp.uint32)
    eng.reach(sk, qs, qs, epoch=0)
    assert eng.closure_refreshes == 1
    eng.reach(sk, qs, qs, epoch=0)  # cached
    assert eng.closure_refreshes == 1
    eng.reach(sk, qs, qs, epoch=1)  # sketch changed -> rebuild
    assert eng.closure_refreshes == 2
    eng.invalidate()
    eng.reach(sk, qs, qs, epoch=1)
    assert eng.closure_refreshes == 3
    # results against the cached closure equal the from-scratch query
    from repro.core import reach as reach_mod

    got = eng.reach(sk, src[:20], dst[:20], epoch=1)
    ref = reach_mod.reach_query(sk, src[:20], dst[:20])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_engine_jit_cache_is_persistent(loaded_sketch):
    sk, src, dst = loaded_sketch
    eng = QueryEngine("jnp")
    eng.edge(sk, src[:64], dst[:64])
    fn = eng._jits["edge"]
    eng.edge(sk, src[:64], dst[:64])
    assert eng._jits["edge"] is fn  # same jitted callable, no re-wrap


def test_resolve_query_backend_env(monkeypatch):
    from repro.core.query_engine import resolve_query_backend

    monkeypatch.setenv("REPRO_QUERY_BACKEND", "pallas")
    assert resolve_query_backend("auto") == "pallas"
    monkeypatch.delenv("REPRO_QUERY_BACKEND")
    assert resolve_query_backend(None) in ("jnp", "pallas")
    with pytest.raises(ValueError):
        resolve_query_backend("nope")


# ---------------------------------------------------------------------------
# checkpoint schema evolution (register-less sketches)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrips_registers(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    rng = np.random.default_rng(2)
    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    sk = GLavaSketch.empty(cfg, jax.random.key(4))
    src, dst, w = _stream(rng, 100)
    sk = sk.update(src, dst, w)
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, sk)
    restored, meta = mgr.restore(like=sk)
    assert meta["step"] == 1
    np.testing.assert_array_equal(
        np.asarray(restored.row_flows), np.asarray(sk.row_flows)
    )
    _assert_registers_fresh(restored, err="restored")


def test_checkpoint_fill_missing_for_old_sketches(tmp_path):
    """A checkpoint saved WITHOUT registers restores into the new schema:
    missing float leaves fill with NaN (stale reads fail loudly instead of
    silently answering 0), are reported, and with_counters rebuilds them
    exactly."""
    from repro.checkpoint.manager import CheckpointManager

    rng = np.random.default_rng(3)
    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    sk = GLavaSketch.empty(cfg, jax.random.key(5))
    src, dst, w = _stream(rng, 100)
    sk = sk.update(src, dst, w)
    mgr = CheckpointManager(tmp_path)
    # old-schema state: counters + hashes only (what a pre-register
    # checkpoint held)
    mgr.save(7, {"counters": sk.counters})
    like = {
        "counters": sk.counters,
        "row_flows": sk.row_flows,
        "col_flows": sk.col_flows,
    }
    with pytest.raises(KeyError):
        mgr.restore(like=like)
    restored, meta = mgr.restore(like=like, fill_missing=True)
    assert sorted(meta["filled_leaves"]) == ["['col_flows']", "['row_flows']"]
    assert np.all(np.isnan(np.asarray(restored["row_flows"])))
    rebuilt = sk.with_counters(restored["counters"])
    _assert_registers_fresh(rebuilt, err="rebuilt from old checkpoint")
    np.testing.assert_array_equal(
        np.asarray(rebuilt.counters), np.asarray(sk.counters)
    )
