"""Neighbor sampler + gLava integrations (GNN degree sketch, recsys
popularity sketch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import SketchConfig
from repro.data.graphs import build_triplets, citation_graph, random_edges
from repro.integration.popularity import InteractionPopularitySketch
from repro.integration.sketch_sampler import StreamingDegreeSketch, sketch_weighted_seeds
from repro.models.gnn.sampler import CSRGraph, sample_subgraph, sampled_block_sizes


def test_csr_and_degrees():
    src = np.array([0, 1, 2, 0, 3], np.int32)
    dst = np.array([1, 2, 0, 2, 0], np.int32)
    g = CSRGraph.from_edges(src, dst, 4)
    np.testing.assert_array_equal(g.degree(np.arange(4)), [2, 1, 2, 0])
    rng = np.random.default_rng(0)
    nbrs = g.sample_neighbors(np.array([0, 3]), 4, rng)
    assert set(nbrs[0]) <= {2, 3}   # in-neighbors of 0
    assert set(nbrs[1]) == {3}      # isolated -> self-loop


def test_sample_subgraph_static_shapes():
    rng = np.random.default_rng(1)
    src, dst = random_edges(500, 4000, rng)
    g = CSRGraph.from_edges(src, dst, 500)
    seeds = rng.choice(500, 16, replace=False).astype(np.int32)
    sub = sample_subgraph(g, seeds, (5, 3), rng)
    n_pad, e_pad = sampled_block_sizes(16, (5, 3))
    assert sub["nodes"].shape == (n_pad,)
    assert sub["edge_src"].shape == (e_pad,)
    assert sub["edge_mask"].all()  # sampler always fills (with replacement)
    # message edges point from sampled neighbor (local id) to its frontier node
    assert sub["edge_dst"][:80].max() < 16


def test_streaming_degree_sketch_overestimates():
    rng = np.random.default_rng(2)
    src, dst = random_edges(300, 5000, rng)
    sk = StreamingDegreeSketch(SketchConfig(depth=4, width_rows=256, width_cols=256))
    for lo in range(0, 5000, 1000):
        sk.observe(src[lo : lo + 1000], dst[lo : lo + 1000])
    est_out = sk.degree_estimates(np.arange(300, dtype=np.uint32), "out")
    exact_out = np.bincount(src, minlength=300)
    assert np.all(est_out >= exact_out - 1e-5)
    # weights form a distribution and favor high-degree nodes
    w = sk.seed_weights(300)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-9)
    hi, lo_ = exact_out.argmax(), exact_out.argmin()
    assert w[hi] > w[lo_]
    seeds = sketch_weighted_seeds(sk, 300, 32, rng)
    assert len(set(seeds.tolist())) == 32


def test_popularity_sketch_negative_sampling():
    rng = np.random.default_rng(3)
    n_items = 2000
    pop = InteractionPopularitySketch(n_items, width_users=512, width_items=1024)
    # items 1..20 are 50x hotter
    hot = rng.integers(1, 21, 20_000).astype(np.uint32)
    cold = rng.integers(21, n_items + 1, 4_000).astype(np.uint32)
    items = np.concatenate([hot, cold])
    users = rng.integers(0, 5000, len(items)).astype(np.uint32)
    pop.observe(users, items)
    est_hot = pop.item_popularity(np.arange(1, 21, dtype=np.uint32)).mean()
    est_cold = pop.item_popularity(np.arange(500, 520, dtype=np.uint32)).mean()
    assert est_hot > 10 * est_cold
    negs = pop.sample_negatives(512, rng)
    frac_hot = np.mean(negs <= 20)
    assert frac_hot > 0.2  # popularity-weighted: hot items over-represented


def test_build_triplets_matches_bruteforce():
    rng = np.random.default_rng(4)
    src, dst = random_edges(20, 60, rng)
    trip = build_triplets(src, dst)
    got = {
        (int(trip["in"][i]), int(trip["out"][i]))
        for i in range(len(trip["in"]))
        if trip["mask"][i] > 0
    }
    want = set()
    for eo in range(60):
        j, i = int(src[eo]), int(dst[eo])
        for ei in range(60):
            if int(dst[ei]) == j and int(src[ei]) != i:
                want.add((ei, eo))
    assert got == want
    assert not trip["truncated"]


def test_build_triplets_budget_truncation():
    # star graph: 50 in-edges (k->0) and 50 out-edges (0->j) => ~2450 triplets
    src = np.concatenate([np.arange(1, 51), np.zeros(50)]).astype(np.int32)
    dst = np.concatenate([np.zeros(50), np.arange(51, 101)]).astype(np.int32)
    trip = build_triplets(src, dst, budget=64)
    assert trip["truncated"]
    assert trip["mask"].sum() == 64
