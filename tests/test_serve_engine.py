"""Serving-engine behaviour tests: ingest/query stats, closure caching,
windowed service, and the full mixed workload."""
import numpy as np
import pytest

from repro.core.sketch import SketchConfig
from repro.serve.engine import SketchServer


@pytest.fixture()
def server():
    return SketchServer(SketchConfig(depth=3, width_rows=128, width_cols=128))


def test_ingest_and_edge_query(server):
    rng = np.random.default_rng(0)
    src = rng.integers(0, 1000, 500).astype(np.uint32)
    dst = rng.integers(0, 1000, 500).astype(np.uint32)
    server.ingest(src, dst)
    est = server.edge_frequency(src[:50], dst[:50])
    assert np.all(est >= 1)
    assert server.stats.edges_ingested == 500


def test_closure_cache_invalidation(server):
    src = np.array([1, 2], np.uint32)
    dst = np.array([2, 3], np.uint32)
    server.ingest(src, dst)
    r1 = server.reachable(np.array([1], np.uint32), np.array([3], np.uint32))
    assert bool(r1[0])
    assert server.stats.closure_refreshes == 1
    # second query: cached closure, no refresh
    server.reachable(np.array([2], np.uint32), np.array([3], np.uint32))
    assert server.stats.closure_refreshes == 1
    # ingest dirties the cache — an additions-only batch is absorbed by the
    # touched-row incremental refresh, not a second full re-squaring
    server.ingest(np.array([3], np.uint32), np.array([4], np.uint32))
    r2 = server.reachable(np.array([1], np.uint32), np.array([4], np.uint32))
    assert bool(r2[0])
    assert server.stats.closure_refreshes == 1
    assert server.stats.closure_incremental_refreshes == 1


def test_windowed_server_expiry():
    server = SketchServer(
        SketchConfig(depth=3, width_rows=128, width_cols=128), window_slices=2
    )
    server.ingest(np.array([10], np.uint32), np.array([20], np.uint32))
    assert server.edge_frequency(np.array([10], np.uint32), np.array([20], np.uint32))[0] == 1
    server.advance_window()
    server.advance_window()  # wraps: slice holding (10,20) zeroed
    est = server.edge_frequency(np.array([10], np.uint32), np.array([20], np.uint32))
    assert est[0] == 0


def test_heavy_hitter_monitor(server):
    rng = np.random.default_rng(1)
    src = rng.integers(0, 100, 2000).astype(np.uint32)
    dst = np.full(2000, 7, np.uint32)  # flood node 7: 100% of in-flow
    server.ingest(src, dst)
    flags = server.heavy_hitters(np.arange(10, dtype=np.uint32), theta=0.5)
    assert flags[7]
    assert not flags[3]


def test_server_standing_subscription(server):
    """The serving engine exposes the session's subscription plane."""
    rng = np.random.default_rng(2)
    sub = server.subscribe(
        server.Query.in_flow(np.arange(8, dtype=np.uint32)),
        every=2,
        name="svc",
    )
    for _ in range(4):
        server.ingest(
            rng.integers(0, 100, 50).astype(np.uint32),
            rng.integers(0, 100, 50).astype(np.uint32),
        )
    events = sub.poll()
    assert sub.ticks == 2 and len(events) == 2
    assert events[-1].epoch == server.stream.epoch
    # the session-wide feed carries the same events (independent drain)
    assert len(list(server.events())) == 2
    assert len(list(server.events())) == 0  # drained
    sub.cancel()


def test_subgraph_weight(server):
    server.ingest(np.array([1, 2], np.uint32), np.array([2, 3], np.uint32))
    w = server.subgraph_weight(np.array([1, 2], np.uint32), np.array([2, 3], np.uint32))
    assert w >= 2.0
    w0 = server.subgraph_weight(np.array([1, 5], np.uint32), np.array([2, 6], np.uint32))
    assert w0 == 0.0
