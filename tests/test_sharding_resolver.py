"""Sharding resolver unit tests: divisibility fallback, axis dedup, rules."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ResolveReport, default_rules, resolve_pspec


@pytest.fixture(scope="module")
def mesh():
    # 1-device "production-shaped" mesh: axis sizes matter, not devices
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    return jax.sharding.Mesh(dev, ("pod", "data", "model"))


def _rules16(mesh):
    # pretend-16-way semantics: use a fake mesh shape via a real Mesh of the
    # production shape is impossible on 1 device, so test the arithmetic
    # against an object exposing .shape
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    return FakeMesh()


def test_divisible_dims_shard(mesh):
    fm = _rules16(mesh)
    rules = {"vocab": ("model",), "embed": ("pod", "data")}
    spec = resolve_pspec(("vocab", "embed"), (32768, 6144), fm, rules)
    assert spec == P("model", ("pod", "data"))


def test_non_divisible_falls_back_replicated():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rep = ResolveReport()
    rules = {"heads": ("model",)}
    spec = resolve_pspec(("heads",), (56,), FakeMesh(), rules, rep, path="wq")
    assert spec == P()
    assert any("56" in f for f in rep.fallbacks)


def test_partial_prefix_used_when_full_product_fails():
    class FakeMesh:
        shape = {"pod": 2, "data": 16}

    rules = {"batch": ("pod", "data")}
    # 16 % 32 != 0 but 16 % 2 == 0 -> shard over pod only
    spec = resolve_pspec(("batch",), (16,), FakeMesh(), rules)
    assert spec == P("pod")


def test_axis_never_reused_across_dims():
    class FakeMesh:
        shape = {"model": 16}

    rules = {"heads": ("model",), "ffn": ("model",)}
    spec = resolve_pspec(("heads", "ffn"), (64, 64), FakeMesh(), rules)
    # second dim must NOT reuse the model axis
    assert spec == P("model")


def test_trailing_nones_trimmed():
    class FakeMesh:
        shape = {"model": 16}

    spec = resolve_pspec((None, "vocab", None), (5, 32, 7), FakeMesh(), {"vocab": ("model",)})
    assert spec == P(None, "model")
