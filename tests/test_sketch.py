"""System-invariant tests for GLavaSketch and baselines (paper Section 3.2)."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CountMin,
    CountSketch,
    GLavaSketch,
    GSketch,
    NodeCountMin,
    SketchConfig,
)


def _stream(seed, n, n_nodes=200, max_w=5):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n).astype(np.uint32)
    dst = rng.integers(0, n_nodes, n).astype(np.uint32)
    w = rng.integers(1, max_w + 1, n).astype(np.float32)
    return jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)


def _exact_counts(src, dst, w):
    cnt = collections.Counter()
    for s, d, wt in zip(np.asarray(src), np.asarray(dst), np.asarray(w)):
        cnt[(int(s), int(d))] += float(wt)
    return cnt


@pytest.fixture(scope="module")
def small_sketch():
    cfg = SketchConfig(depth=4, width_rows=128, width_cols=128)
    return GLavaSketch.empty(cfg, jax.random.key(0))


def test_ingest_backends_bit_equal(small_sketch):
    src, dst, w = _stream(0, 700)
    a = small_sketch.update(src, dst, w, backend="scatter")
    b = small_sketch.update(src, dst, w, backend="onehot")
    c = small_sketch.update_sequential(src, dst, w)
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(c.counters))


def test_mass_preservation(small_sketch):
    """Every sketch's total mass equals the total stream weight exactly."""
    src, dst, w = _stream(1, 300)
    sk = small_sketch.update(src, dst, w)
    per_sketch = np.asarray(jnp.sum(sk.counters, axis=(1, 2)))
    np.testing.assert_allclose(per_sketch, float(jnp.sum(w)), rtol=0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 200))
def test_linearity_property(seed, n):
    """sketch(S1 || S2) == sketch(S1) + sketch(S2) — paper Section 6.3."""
    cfg = SketchConfig(depth=2, width_rows=64, width_cols=64)
    empty = GLavaSketch.empty(cfg, jax.random.key(7))
    src, dst, w = _stream(seed, n)
    k = n // 2
    whole = empty.update(src, dst, w)
    parts = empty.update(src[:k], dst[:k], w[:k]).merge(
        empty.update(src[k:], dst[k:], w[k:])
    )
    np.testing.assert_array_equal(np.asarray(whole.counters), np.asarray(parts.counters))


def test_turnstile_delete_roundtrip(small_sketch):
    src, dst, w = _stream(2, 150)
    sk = small_sketch.update(src, dst, w).delete(src, dst, w)
    np.testing.assert_array_equal(
        np.asarray(sk.counters), np.asarray(small_sketch.counters)
    )


def test_space_is_sublinear_constant_in_stream_length(small_sketch):
    """Constraint 1 of Section 3.2: |S_G| independent of |G|."""
    src, dst, w = _stream(3, 2000)
    sk = small_sketch.update(src, dst, w)
    assert sk.counters.shape == small_sketch.counters.shape
    assert sk.config.space_bytes() == 4 * 4 * 128 * 128


def test_nonsquare_uses_two_hashes():
    cfg = SketchConfig(depth=3, width_rows=256, width_cols=64)
    sk = GLavaSketch.empty(cfg, jax.random.key(1))
    assert not cfg.is_square
    assert not np.array_equal(np.asarray(sk.row_hash.a), np.asarray(sk.col_hash.a))
    src, dst, w = _stream(4, 100)
    sk = sk.update(src, dst, w)
    assert sk.counters.shape == (3, 256, 64)
    np.testing.assert_allclose(
        np.asarray(sk.counters.sum(axis=(1, 2))), float(w.sum())
    )


def test_square_shares_hash():
    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    sk = GLavaSketch.empty(cfg, jax.random.key(1))
    np.testing.assert_array_equal(
        np.asarray(sk.row_hash.a), np.asarray(sk.col_hash.a)
    )


def test_undirected_symmetry():
    cfg = SketchConfig(depth=2, width_rows=64, width_cols=64, directed=False)
    sk = GLavaSketch.empty(cfg, jax.random.key(5))
    src, dst, w = _stream(6, 120)
    sk = sk.update(src, dst, w)
    c = np.asarray(sk.counters)
    np.testing.assert_allclose(c, np.transpose(c, (0, 2, 1)))


def test_conservative_update_dominated_by_vanilla():
    """CU estimates are still over-estimates but never exceed vanilla's."""
    from repro.core import queries

    cfg = SketchConfig(depth=3, width_rows=32, width_cols=32)
    empty = GLavaSketch.empty(cfg, jax.random.key(2))
    src, dst, w = _stream(7, 400, n_nodes=100)
    vanilla = empty.update(src, dst, w)
    cu = empty.update_conservative(src, dst, w)
    exact = _exact_counts(src, dst, w)
    qs, qd = src[:50], dst[:50]
    est_v = np.asarray(queries.edge_query(vanilla, qs, qd))
    est_c = np.asarray(queries.edge_query(cu, qs, qd))
    ex = np.array(
        [exact[(int(s), int(d))] for s, d in zip(np.asarray(qs), np.asarray(qd))]
    )
    assert np.all(est_c >= ex - 1e-6)
    assert np.all(est_c <= est_v + 1e-6)


def test_countmin_edge_query_overestimates():
    src, dst, w = _stream(8, 500, n_nodes=80)
    cm = CountMin.empty(4, 512, jax.random.key(0)).update(src, dst, w)
    exact = _exact_counts(src, dst, w)
    est = np.asarray(cm.edge_query(src[:64], dst[:64]))
    ex = np.array(
        [exact[(int(s), int(d))] for s, d in zip(np.asarray(src[:64]), np.asarray(dst[:64]))]
    )
    assert np.all(est >= ex - 1e-6)


def test_node_countmin_flows():
    src, dst, w = _stream(9, 400, n_nodes=50)
    ncm = NodeCountMin.empty(4, 256, jax.random.key(0)).update(src, dst, w)
    outs = np.asarray(ncm.out_flow(jnp.arange(50, dtype=jnp.uint32)))
    exact_out = np.zeros(50)
    for s, wt in zip(np.asarray(src), np.asarray(w)):
        exact_out[int(s)] += float(wt)
    assert np.all(outs >= exact_out - 1e-5)


def test_countsketch_unbiased_ish():
    """CountSketch median estimate should straddle the truth, not only
    overestimate (unlike CountMin)."""
    src, dst, w = _stream(10, 1000, n_nodes=60)
    from repro.core.hashing import mix_keys

    cs = CountSketch.empty(5, 256, jax.random.key(0))
    keys = mix_keys(src, dst)
    cs = cs.update(keys, w)
    exact = _exact_counts(src, dst, w)
    qk = mix_keys(src[:100], dst[:100])
    est = np.asarray(cs.query(qk))
    ex = np.array(
        [exact[(int(s), int(d))] for s, d in zip(np.asarray(src[:100]), np.asarray(dst[:100]))]
    )
    err = est - ex
    # Signed errors in both directions and small on average.
    assert np.abs(np.mean(err)) < np.mean(np.abs(ex)) * 0.5 + 1.0


def test_gsketch_partition_and_query():
    src, dst, w = _stream(11, 600, n_nodes=100)
    sample = np.asarray(src[:100])
    gs = GSketch.from_sample(4, 1024, 4, sample, jax.random.key(0))
    gs = gs.update(src, dst, w)
    exact = _exact_counts(src, dst, w)
    est = np.asarray(gs.edge_query(src[:64], dst[:64]))
    ex = np.array(
        [exact[(int(s), int(d))] for s, d in zip(np.asarray(src[:64]), np.asarray(dst[:64]))]
    )
    assert np.all(est >= ex - 1e-6)


def test_for_error_sizing():
    cfg = SketchConfig.for_error(epsilon=0.01, delta=0.01)
    assert cfg.width_rows == int(np.ceil(np.e / np.sqrt(0.01)))
    assert cfg.depth == int(np.ceil(np.log(100)))


def test_counter_exactness_guard():
    """fp32 counters are exact for integer-valued mass below 2**24."""
    cfg = SketchConfig(depth=1, width_rows=2, width_cols=2)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src = jnp.zeros(1000, jnp.uint32)
    dst = jnp.zeros(1000, jnp.uint32)
    sk = sk.update(src, dst)
    assert float(sk.counters.sum()) == 1000.0
