"""Standing-query plane tests: subscription lifecycle (register → compiled
plan → mutation-driven re-eval → event emission), incremental closure
refresh (element-identity to from-scratch closures under random
ingest/delete/advance_window sequences, the 1-full-build + N-incremental
acceptance count, staleness-budget fallback), subscription results
bit-matching the one-shot ``gs.query`` oracle at every tick, the
empty-QueryBatch fast path, and θ validation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (
    GraphStream,
    IngestReceipt,
    Query,
    QueryBatch,
    SketchConfig,
    Subscription,
    validate_theta,
)
from repro.core import GLavaSketch, QueryEngine, reach
from repro.core.query_engine import CLOSURE_REFRESH_PAD_T


CFG = SketchConfig(depth=3, width_rows=128, width_cols=128)


def _open(**kw):
    return GraphStream.open(
        CFG, ingest_backend="scatter", query_backend="jnp", **kw
    )


def _batches(rng, n, size=12, nodes=400):
    return [
        (
            rng.integers(0, nodes, size).astype(np.uint32),
            rng.integers(0, nodes, size).astype(np.uint32),
        )
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# satellite: empty QueryBatch returns [] without touching the engine
# ---------------------------------------------------------------------------


def test_empty_batch_returns_empty_without_engine():
    gs = _open()
    gs.ingest([1, 2], [2, 3])
    gs.query(Query.edge(1, 2))  # warm: some dispatches exist
    before = dict(gs.engine.dispatches)
    served = gs.stats.queries_served
    assert gs.query(QueryBatch([])) == []
    assert gs.query() == []
    assert dict(gs.engine.dispatches) == before  # engine untouched
    assert gs.stats.queries_served == served


def test_empty_batch_does_not_flush():
    gs = _open()
    gs.ingest(np.arange(64, dtype=np.uint32), np.arange(64, dtype=np.uint32))
    inflight = len(gs._inflight)
    assert gs.query(QueryBatch([])) == []
    assert len(gs._inflight) == inflight  # no flush either


# ---------------------------------------------------------------------------
# satellite: θ validation (0 < θ <= 1) at every construction site
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "bad", [0.0, -0.5, 1.5, 600.0, float("nan"), float("inf"), "half", None]
)
def test_theta_validation_rejects(bad):
    with pytest.raises(ValueError):
        validate_theta(bad)
    with pytest.raises(ValueError):
        Query.heavy(7, theta=bad)
    gs = _open()
    with pytest.raises(ValueError):
        gs.monitor([1], [2], np.ones(1, np.float32), watch=2, theta=bad)


def test_theta_validation_accepts_boundaries():
    assert validate_theta(1.0) == 1.0
    assert validate_theta(1e-9) == 1e-9
    assert Query.heavy(7, theta=0.5).theta == 0.5


def test_subscription_validates_every_and_batch():
    gs = _open()
    with pytest.raises(ValueError):
        gs.subscribe(every=1)  # no queries
    with pytest.raises(ValueError):
        gs.subscribe(Query.in_flow(1), every=0)


# ---------------------------------------------------------------------------
# subscription lifecycle: registration -> re-eval cadence -> events
# ---------------------------------------------------------------------------


def test_subscription_event_cadence_and_payload():
    gs = _open()
    rng = np.random.default_rng(0)
    seen = []
    sub = gs.subscribe(
        Query.in_flow(np.arange(6, dtype=np.uint32)),
        Query.edge(1, 2),
        every=3,
        on_result=seen.append,
        name="cadence",
    )
    assert isinstance(sub, Subscription)
    for s, d in _batches(rng, 7):
        gs.ingest(s, d)
    # 7 mutations, every=3 -> ticks after mutations 3 and 6
    assert sub.ticks == 2
    events = sub.poll()
    assert [e.tick for e in events] == [1, 2]
    assert [e.epoch for e in events] == [3, 6]
    assert seen == events  # callback saw the same events, in order
    ev = events[-1]
    assert ev.subscription_id == sub.id and ev.name == "cadence"
    assert ev.timestamp > 0 and ev.alarm is None
    assert len(ev.results) == 2
    assert ev.results[0].query is sub.batch[0]  # request-ordered
    # the session-wide feed carries both events
    assert [e.tick for e in gs.events()] == [1, 2]
    assert list(gs.events()) == []  # drained
    assert sub.poll() == []


def test_subscription_cancel_and_multiple_subscribers():
    gs = _open()
    rng = np.random.default_rng(1)
    a = gs.subscribe(Query.in_flow(1), every=1)
    b = gs.subscribe(Query.out_flow(2), every=2)
    for s, d in _batches(rng, 2):
        gs.ingest(s, d)
    assert (a.ticks, b.ticks) == (2, 1)
    a.cancel()
    a.cancel()  # idempotent
    assert not a.active
    assert gs.subscriptions == (b,)
    pending = a.pending
    for s, d in _batches(rng, 2):
        gs.ingest(s, d)
    assert (a.ticks, b.ticks) == (2, 2)  # a stopped, b kept ticking
    assert a.pending == pending  # cancelled: no new events delivered


def test_subscription_alarm_predicate():
    gs = _open()
    sub = gs.subscribe(
        Query.in_flow(7),
        every=1,
        alarm=lambda results: float(np.asarray(results[0].value)) > 100.0,
    )
    gs.ingest(np.zeros(5, np.uint32), np.full(5, 7, np.uint32))
    assert sub.poll()[-1].alarm is False
    gs.ingest(
        np.zeros(20, np.uint32),
        np.full(20, 7, np.uint32),
        np.full(20, 10.0, np.float32),
    )
    assert sub.poll()[-1].alarm is True


def test_subscription_fires_on_window_and_delete_mutations():
    gs = GraphStream.open(
        CFG, window_slices=2, ingest_backend="scatter", query_backend="jnp"
    )
    sub = gs.subscribe(Query.edge(10, 20), every=1)
    gs.ingest([10], [20])
    assert float(np.asarray(sub.poll()[-1].results[0].value)) == 1.0
    gs.advance_window()
    gs.advance_window()  # expiry wraps: the slice holding (10,20) zeroes
    assert sub.ticks == 3
    assert float(np.asarray(sub.poll()[-1].results[0].value)) == 0.0

    gs2 = _open()
    sub2 = gs2.subscribe(Query.edge(1, 2), every=1)
    gs2.ingest([1, 1], [2, 2])
    gs2.delete([1], [2])
    ticks = sub2.poll()
    assert [float(np.asarray(e.results[0].value)) for e in ticks] == [2.0, 1.0]


def test_ingest_returns_receipt_with_touched_keys():
    gs = _open()
    r = gs.ingest(np.asarray([5, 5, 9], np.uint32), np.asarray([7, 8, 9], np.uint32))
    assert isinstance(r, IngestReceipt)
    assert r.epoch == 1 and r.n_edges == 3
    np.testing.assert_array_equal(r.touched_keys, [5, 9])  # unique src keys
    # deletes are not additions-only: no touched set
    r2 = gs.delete(np.asarray([5], np.uint32), np.asarray([7], np.uint32))
    assert r2.touched_keys is None
    # tracking stays poisoned (hot path skips the scans) until the next
    # closure sync forces a full rebuild
    r3 = gs.ingest(np.asarray([1], np.uint32), np.asarray([2], np.uint32))
    assert r3.touched_keys is None


# ---------------------------------------------------------------------------
# incremental closure refresh: exactness, acceptance count, budget fallback
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refresh_closure_matches_from_scratch(seed):
    """Property: after any additions-only history, refresh_closure(touched)
    is element-identical to a from-scratch transitive closure."""
    rng = np.random.default_rng(seed)
    sk = GLavaSketch.empty(
        SketchConfig(depth=2, width_rows=64, width_cols=64), jax.random.key(0)
    )
    eng = QueryEngine("jnp")
    src = jnp.asarray(rng.integers(0, 300, 150), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 300, 150), jnp.uint32)
    sk = sk.update(src, dst)
    eng.closure_for(sk, epoch=0)  # seed the cache: 1 full build
    epoch = 0
    for step in range(rng.integers(1, 4)):
        n = int(rng.integers(1, 10))
        s2 = rng.integers(0, 300, n).astype(np.uint32)
        d2 = rng.integers(0, 300, n).astype(np.uint32)
        sk = sk.update(jnp.asarray(s2), jnp.asarray(d2))
        epoch += 1
        got = eng.refresh_closure(sk, np.unique(s2), epoch=epoch)
        want = reach.transitive_closure(sk.counters)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=f"seed {seed} step {step}"
        )
    assert eng.closure_refreshes == 1  # never rebuilt from scratch again
    assert eng.closure_incremental_refreshes >= 1


def test_refresh_closure_pad_boundary_exact():
    """Touched counts straddling the pad width (T = 64) stay exact."""
    rng = np.random.default_rng(3)
    sk = GLavaSketch.empty(
        SketchConfig(depth=2, width_rows=512, width_cols=512), jax.random.key(1)
    )
    eng = QueryEngine("jnp")
    sk = sk.update(
        jnp.asarray(rng.integers(0, 2000, 400), jnp.uint32),
        jnp.asarray(rng.integers(0, 2000, 400), jnp.uint32),
    )
    eng.closure_for(sk, epoch=0)
    for i, n in enumerate(
        [CLOSURE_REFRESH_PAD_T - 1, CLOSURE_REFRESH_PAD_T, CLOSURE_REFRESH_PAD_T + 1]
    ):
        s2 = np.arange(5000 + 100 * i, 5000 + 100 * i + n, dtype=np.uint32)
        d2 = rng.integers(0, 2000, n).astype(np.uint32)
        sk = sk.update(jnp.asarray(s2), jnp.asarray(d2))
        got = eng.refresh_closure(sk, s2, epoch=i + 1)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(reach.transitive_closure(sk.counters))
        )
    assert eng.closure_refreshes == 1
    assert eng.closure_incremental_refreshes == 3


def test_refresh_closure_fallback_paths():
    rng = np.random.default_rng(4)
    sk = GLavaSketch.empty(
        SketchConfig(depth=2, width_rows=64, width_cols=64), jax.random.key(2)
    )
    sk = sk.update(
        jnp.asarray(rng.integers(0, 100, 80), jnp.uint32),
        jnp.asarray(rng.integers(0, 100, 80), jnp.uint32),
    )
    # no cached closure -> full build
    eng = QueryEngine("jnp")
    eng.refresh_closure(sk, np.asarray([1], np.uint32), epoch=0)
    assert (eng.closure_refreshes, eng.closure_incremental_refreshes) == (1, 0)
    # touched=None (delete / unknown history) -> full build
    eng.refresh_closure(sk, None, epoch=1)
    assert (eng.closure_refreshes, eng.closure_incremental_refreshes) == (2, 0)
    # touched fraction above the budget -> full build
    eng.refresh_closure(sk, np.arange(60, dtype=np.uint32), epoch=2)
    assert (eng.closure_refreshes, eng.closure_incremental_refreshes) == (3, 0)
    # small touched set -> incremental
    eng.refresh_closure(sk, np.arange(4, dtype=np.uint32), epoch=3)
    assert (eng.closure_refreshes, eng.closure_incremental_refreshes) == (3, 1)
    # fresh epoch -> no-op
    eng.refresh_closure(sk, np.arange(4, dtype=np.uint32), epoch=3)
    assert (eng.closure_refreshes, eng.closure_incremental_refreshes) == (3, 1)
    # empty touched set retags without counting
    eng.refresh_closure(sk, np.zeros(0, np.uint32), epoch=4)
    assert (eng.closure_refreshes, eng.closure_incremental_refreshes) == (3, 1)
    assert eng._closure_epoch == 4


def test_refresh_closure_staleness_budget():
    rng = np.random.default_rng(5)
    sk = GLavaSketch.empty(
        SketchConfig(depth=2, width_rows=64, width_cols=64), jax.random.key(3)
    )
    sk = sk.update(
        jnp.asarray(rng.integers(0, 100, 80), jnp.uint32),
        jnp.asarray(rng.integers(0, 100, 80), jnp.uint32),
    )
    eng = QueryEngine("jnp", closure_staleness_budget=2)
    eng.closure_for(sk, epoch=0)
    for epoch in range(1, 4):
        sk = sk.update(jnp.asarray([epoch], jnp.uint32), jnp.asarray([0], jnp.uint32))
        eng.refresh_closure(sk, np.asarray([epoch], np.uint32), epoch=epoch)
    # budget 2: refreshes at epochs 1, 2 incremental; epoch 3 rebuilt full
    assert eng.closure_incremental_refreshes == 2
    assert eng.closure_refreshes == 2


# ---------------------------------------------------------------------------
# THE acceptance property: reach subscription over N batches = 1 full build
# + N incremental refreshes, bit-identical to the one-shot oracle per tick
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_reach_subscription_incremental_and_oracle_identical(seed):
    rng = np.random.default_rng(seed)
    gs = _open()
    oracle = _open()  # replayed mutations, fresh-engine one-shot pulls

    qs = rng.integers(0, 400, 16).astype(np.uint32)
    qd = rng.integers(0, 400, 16).astype(np.uint32)
    workload = QueryBatch(
        [
            Query.reach(qs, qd),
            Query.in_flow(qs[:8]),
            Query.heavy(qs[:4], theta=0.01),
            Query.edge(qs[:8], qd[:8]),
        ]
    )
    sub = gs.subscribe(workload, every=1, name="acceptance")

    n_batches = 6
    seed_batch = _batches(rng, 1, size=60)[0]
    batches = [seed_batch] + _batches(rng, n_batches - 1)
    for s, d in batches:
        gs.ingest(s, d)
        oracle.ingest(s, d)
        # one-shot oracle: a FRESH engine answers from scratch
        oracle.engine.invalidate()
        want = oracle.query(QueryBatch(list(workload)))
        got = sub.poll()[-1].results
        for i, (g, w) in enumerate(zip(got, want)):
            if isinstance(g.value, tuple):
                for gg, ww in zip(g.value, w.value):
                    np.testing.assert_array_equal(
                        np.asarray(gg), np.asarray(ww),
                        err_msg=f"seed {seed} slot {i}",
                    )
            else:
                np.testing.assert_array_equal(
                    np.asarray(g.value), np.asarray(w.value),
                    err_msg=f"seed {seed} slot {i}",
                )

    # at most 1 full closure build; every other tick refreshed incrementally
    assert gs.engine.closure_refreshes == 1
    assert gs.engine.closure_incremental_refreshes == n_batches - 1
    assert gs.stats.subscription_ticks == n_batches


def test_subscription_delete_forces_one_full_rebuild_then_incremental():
    rng = np.random.default_rng(9)
    gs = _open()
    sub = gs.subscribe(Query.reach(1, 2), every=1)
    for s, d in _batches(rng, 3):
        gs.ingest(s, d)
    assert gs.engine.closure_refreshes == 1
    assert gs.engine.closure_incremental_refreshes == 2
    gs.delete([1], [2])  # not additions-only -> full rebuild on next tick
    assert gs.engine.closure_refreshes == 2
    for s, d in _batches(rng, 2):
        gs.ingest(s, d)
    assert gs.engine.closure_refreshes == 2  # back to incremental
    assert gs.engine.closure_incremental_refreshes == 4
    assert sub.ticks == 6


# ---------------------------------------------------------------------------
# delete-driven rebuild property: any interleaving of ingest / delete /
# advance serves reach (and register families) bit-identical to an oracle
# that replays the same mutations and rebuilds from scratch every tick
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_closure_under_interleaved_deletes_matches_oracle(seed):
    """Property: the subscription plane's closure maintenance (incremental
    refreshes, delete-poisoned full rebuilds, window expiry) never drifts
    from a from-scratch oracle, no matter how ingest / delete / advance
    interleave.  Deletes replay earlier edges with negated weights, so the
    turnstile path must cancel exactly."""
    rng = np.random.default_rng(seed)
    gs = _open(window_slices=4)
    oracle = _open(window_slices=4)

    qs = rng.integers(0, 400, 12).astype(np.uint32)
    qd = rng.integers(0, 400, 12).astype(np.uint32)
    workload = QueryBatch(
        [Query.reach(qs, qd), Query.in_flow(qs[:6]), Query.edge(qs[:6], qd[:6])]
    )
    sub = gs.subscribe(workload, every=1, name="oracle-check")

    history = []  # ingested (src, dst) batches, the delete pool
    n_deletes = 0
    for step in range(10):
        op = rng.choice(["ingest", "ingest", "delete", "advance"])
        if op == "delete" and history:
            s, d = history[rng.integers(0, len(history))]
            k = max(1, s.size // 2)
            gs.delete(s[:k], d[:k])
            oracle.delete(s[:k], d[:k])
            n_deletes += 1
        elif op == "advance":
            gs.advance_window()
            oracle.advance_window()
        else:
            s, d = _batches(rng, 1)[0]
            history.append((s, d))
            gs.ingest(s, d)
            oracle.ingest(s, d)
        oracle.engine.invalidate()  # from-scratch answers, every tick
        want = oracle.query(QueryBatch(list(workload)))
        got = sub.poll()[-1].results
        for i, (g, w) in enumerate(zip(got, want)):
            gv = g.value if isinstance(g.value, tuple) else (g.value,)
            wv = w.value if isinstance(w.value, tuple) else (w.value,)
            for gg, ww in zip(gv, wv):
                np.testing.assert_array_equal(
                    np.asarray(gg), np.asarray(ww),
                    err_msg=f"seed {seed} step {step} op {op} slot {i}",
                )
    assert sub.ticks == 10
    # every delete poisons touched-key tracking: the NEXT closure sync is
    # a full rebuild (cheaper histories may coalesce several into one)
    if n_deletes:
        assert gs.engine.closure_refreshes >= 1
