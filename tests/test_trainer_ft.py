"""Fault-tolerance tests: atomic checkpointing, crash-exact resume, failure
injection, straggler watchdog, reshard-on-restore, sketched compression."""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.train import compression as comp
from repro.train import optimizer as opt_mod
from repro.train.trainer import (
    TrainerConfig,
    compressed_data_parallel_step,
    train_loop,
)


def _toy_problem(seed=0):
    """Tiny least-squares problem with a known optimum."""
    rng = np.random.default_rng(seed)
    w_true = rng.normal(0, 1, (8, 4)).astype(np.float32)

    def init_state(key):
        params = {"w": jnp.zeros((8, 4), jnp.float32)}
        return {"params": params, "opt": opt_mod.init_adamw(OPT, params)}

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def batches():
        r = np.random.default_rng(1)
        while True:
            x = r.normal(0, 1, (32, 8)).astype(np.float32)
            yield {"x": x, "y": x @ w_true}

    def step(state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
        p, o, m = opt_mod.apply_adamw(OPT, state["opt"], state["params"], grads)
        return {"params": p, "opt": o}, {"loss": loss, **m}

    return init_state, step, batches, loss_fn


OPT = opt_mod.AdamWConfig(lr=3e-2, warmup_steps=5, total_steps=200, weight_decay=0.0)


def test_train_loop_converges(tmp_path):
    init_state, step, batches, _ = _toy_problem()
    cfg = TrainerConfig(total_steps=60, checkpoint_dir=str(tmp_path), log_every=0)
    res = train_loop(init_state, step, batches(), cfg)
    assert res.history[-1]["loss"] < res.history[0]["loss"] * 0.1


def test_crash_and_resume_exact(tmp_path):
    """Train 60 steps straight vs crash-at-30 + restart: identical params
    (batches are step-deterministic, checkpoints carry the step counter)."""
    init_state, step, batches, _ = _toy_problem()

    def det_batches():
        # deterministic per step so resume sees the same stream
        r = np.random.default_rng(2)
        xs = [
            {"x": (x := r.normal(0, 1, (32, 8)).astype(np.float32)),
             "y": x @ np.ones((8, 4), np.float32)}
            for _ in range(100)
        ]
        return xs

    xs = det_batches()

    def stream(start=0):
        return iter(xs[start:])

    straight = train_loop(
        init_state, step,
        iter(xs),
        TrainerConfig(total_steps=60, checkpoint_dir=str(tmp_path / "a"),
                      checkpoint_every=30, log_every=0),
    )

    cfg_crash = TrainerConfig(
        total_steps=60, checkpoint_dir=str(tmp_path / "b"),
        checkpoint_every=30, log_every=0, fail_at_step=45,
    )
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(init_state, step, iter(xs), cfg_crash)
    # restart: resumes from the step-30 checkpoint, replays the stream window
    cfg_resume = dataclasses.replace(cfg_crash, fail_at_step=None)
    mgr = CheckpointManager(str(tmp_path / "b"))
    start = mgr.latest_step()
    assert start == 30
    resumed = train_loop(init_state, step, iter(xs[start:]), cfg_resume)
    assert resumed.resumed_from == 30
    np.testing.assert_allclose(
        np.asarray(straight.state["params"]["w"]),
        np.asarray(resumed.state["params"]["w"]),
        rtol=0, atol=0,
    )


def test_checkpoint_atomicity_survives_partial_tmp(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"a": jnp.arange(8.0)}
    mgr.save(1, state)
    # fake a crashed half-written save
    (tmp_path / "step_0000000002.tmp-dead").mkdir()
    (tmp_path / "step_0000000002.tmp-dead" / "arrays.npz").write_bytes(b"junk")
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(like=state)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(8.0))
    mgr.save(2, {"a": jnp.ones(8)})  # gc removes the orphan
    assert not list(tmp_path.glob("*.tmp-*"))


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray(float(s))})
    assert mgr.all_steps() == [3, 4]


def test_restore_reshard_roundtrip(tmp_path):
    """Save replicated, restore with an explicit sharding (the elastic-
    scaling path; on 1 device the sharding is trivial but exercises the
    device_put(arr, sharding) branch)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(5, state, {"step": 5})
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, meta = mgr.restore(like=state, shardings=sh)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


def test_straggler_watchdog(tmp_path, monkeypatch):
    init_state, step, batches, _ = _toy_problem()

    slow = {"n": 0}
    real_step = step

    def slow_step(state, batch):
        return real_step(state, batch)

    cfg = TrainerConfig(total_steps=30, log_every=0, watchdog_factor=1e-9)
    res = train_loop(init_state, slow_step, batches(), cfg)
    # with an absurd watchdog factor every post-warmup step is flagged
    assert len(res.straggler_steps) > 0


def test_compressed_step_converges():
    """Sketched-gradient training must still drive the loss down and the
    compressed update must correlate with the true gradient."""
    init_state, _, batches, loss_fn = _toy_problem()
    ccfg = comp.CompressorConfig(depth=5, width=512, top_k=16, momentum=0.0)
    step = compressed_data_parallel_step(loss_fn, OPT, ccfg)

    key = jax.random.key(0)
    params = {"w": jnp.zeros((8, 4), jnp.float32)}
    state = {
        "params": params,
        "opt": opt_mod.init_adamw(OPT, params),
        "comp": comp.init_compressor(ccfg, 32, jax.random.key(1)),
    }
    jstep = jax.jit(step)
    bs = batches()
    losses = []
    for _ in range(60):
        state, m = jstep(state, next(bs))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.3, losses[::10]


def test_compression_roundtrip_error_feedback():
    """Residual mass is carried, not dropped: two identical gradients with
    error feedback transmit more mass than one round alone."""
    ccfg = comp.CompressorConfig(depth=5, width=256, top_k=4, momentum=0.0)
    st = comp.init_compressor(ccfg, 64, jax.random.key(0))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    up1, st = comp.roundtrip(st, g)
    up2, st = comp.roundtrip(st, g)
    # error feedback should surface previously-suppressed coordinates
    assert float(jnp.abs(st.error).sum()) < 2 * float(jnp.abs(g).sum())
    total = np.asarray(jnp.abs(up1) + jnp.abs(up2) > 0).sum()
    assert total > np.asarray(jnp.abs(up1) > 0).sum()
