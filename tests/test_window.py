"""Sliding-window sketch tests (paper Section 6.1.1 time-window deletion)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GLavaSketch, SketchConfig, SlidingWindowSketch, queries


def test_window_expiry_drops_old_slices():
    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    win = SlidingWindowSketch.empty(cfg, n_slices=3, key=jax.random.key(0))
    e = lambda s, d: (jnp.asarray([s], jnp.uint32), jnp.asarray([d], jnp.uint32))

    win = win.update(*e(1, 2))          # slice 0
    win = win.advance().update(*e(3, 4))  # slice 1
    win = win.advance().update(*e(5, 6))  # slice 2
    sk = win.window_sketch()
    assert float(sk.counters[0].sum()) == 3.0

    # Advancing wraps onto slice 0 and expires edge (1,2).
    win = win.advance().update(*e(7, 8))
    sk = win.window_sketch()
    assert float(sk.counters[0].sum()) == 3.0
    est = queries.edge_query(
        sk, jnp.asarray([1], jnp.uint32), jnp.asarray([2], jnp.uint32)
    )
    # (1,2) expired; with w=64 and 3 remaining edges a collision is unlikely.
    assert float(est[0]) == 0.0


def test_window_sum_equals_manual_merge():
    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    win = SlidingWindowSketch.empty(cfg, n_slices=4, key=jax.random.key(1))
    rng = np.random.default_rng(0)
    all_src, all_dst = [], []
    for _ in range(4):
        src = jnp.asarray(rng.integers(0, 100, 20), jnp.uint32)
        dst = jnp.asarray(rng.integers(0, 100, 20), jnp.uint32)
        win = win.update(src, dst).advance()
        all_src.append(src)
        all_dst.append(dst)
    # Ring never wrapped past capacity-1 advances? We advanced 4 times on 4
    # slices: the last advance wrapped to slice 0 and zeroed it.
    sk_win = win.window_sketch()
    ref = GLavaSketch.empty(cfg, jax.random.key(1))
    ref = ref.update(jnp.concatenate(all_src[1:]), jnp.concatenate(all_dst[1:]))
    # Hash family of window template and ref may differ (different key paths).
    # Compare total mass only for the wrap effect:
    assert float(sk_win.counters[0].sum()) == 60.0


def test_decay_variant():
    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    sk = GLavaSketch.empty(cfg, jax.random.key(2))
    src = jnp.asarray([1, 2], jnp.uint32)
    dst = jnp.asarray([3, 4], jnp.uint32)
    sk = sk.update(src, dst).scale(0.5)
    assert float(sk.counters[0].sum()) == 1.0
